"""Quantized KV page subsystem: code math round-trips, write-scatter
algebra, in-kernel dequant parity, COW-fork scale independence, and the
engine-level logits-closeness guard across every paged kernel path.

The plan's contract (mirrors the scheme-swap guard in test_plan.py):
``kv_dtype`` may change the bytes behind every attention read and which
kernel reads them — never correctness beyond the dtype-derived tolerance
of :func:`repro.kernels.quant.logits_guard_tol`.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.core.plan import make_plan
from repro.kernels import quant, ref
from repro.serving import kvquant

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")

SPECS = [quant.INT8] + ([quant.FP8] if quant.fp8_supported() else [])
SPEC_IDS = [s.name for s in SPECS]

TOL = dict(rtol=2e-5, atol=2e-5)


def _roundtrip_ok(x, spec):
    """quantize_pages -> dequantize_pages error within the analytic bound."""
    codes, steps = kvquant.quantize_pages(jnp.asarray(x, jnp.float32), spec)
    y = kvquant.dequantize_pages(codes, steps)
    bound = quant.roundtrip_bound(
        jnp.asarray(x, jnp.float32), steps[..., None, :], spec)
    err = jnp.abs(y - jnp.asarray(x, jnp.float32))
    # small fp slack: the bound itself is computed in f32
    assert bool(jnp.all(err <= bound * (1 + 1e-5) + 1e-30)), (
        spec.name, float(jnp.max(err - bound)))
    return codes, steps, y


# ---------------------------------------------------------------------------
# Round-trip error vs the analytic bound (hypothesis + edge cases)
# ---------------------------------------------------------------------------


@given(st.sampled_from(SPECS),
       st.integers(min_value=1, max_value=3),
       st.integers(min_value=1, max_value=3),
       st.sampled_from([1, 4, 8]),
       st.floats(min_value=-3.0, max_value=3.0),
       st.booleans())
def test_roundtrip_error_bounded(spec, npages, hk, d, log_scale, outlier):
    rng = np.random.default_rng(npages * 100 + hk * 10 + d)
    x = rng.normal(size=(npages, 8, hk, d)) * 10.0 ** log_scale
    if outlier:
        # one huge element per page: the shared step grows, every other
        # element's absolute error grows with it — the bound must track
        x[:, 0, 0, 0] *= 1e4
    _roundtrip_ok(x, spec)


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_all_zero_pages_roundtrip_exactly(spec):
    x = np.zeros((3, 8, 2, 4), np.float32)
    codes, steps, y = _roundtrip_ok(x, spec)
    # zero content -> step exactly 0.0 (the "empty page" sentinel), zero
    # codes, and a bitwise-zero decode
    assert bool(jnp.all(steps == 0.0))
    assert bool(jnp.all(codes.astype(jnp.float32) == 0.0))
    assert bool(jnp.all(y == 0.0))


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_single_outlier_row_keeps_bound(spec):
    """A single-outlier page stretches the shared step by 1e6: small
    elements collapse to few (or zero) codes but stay within the bound,
    and the outlier itself round-trips at its relative precision."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1, 8, 1, 8)).astype(np.float32)
    x[0, 3, 0, 5] = 1e6
    _, _, y = _roundtrip_ok(x, spec)
    rel = float(jnp.abs(y[0, 3, 0, 5] - 1e6) / 1e6)
    assert rel <= (0.5 / spec.qmax if spec.is_int else 2.0 ** -4) * 1.001


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_bf16_subnormal_pages_roundtrip(spec):
    """Pages of bf16 subnormals (smallest magnitudes the activation dtype
    can store) must quantize without inf/nan steps or bound violations —
    including when XLA's flush-to-zero collapses the subnormal step
    itself (the decode is then exactly zero, error ~1e-40)."""
    tiny = np.float32(2.0 ** -133)             # bf16 subnormal range
    x = (np.asarray(jnp.asarray(
        np.array([[tiny, -tiny, 2 * tiny, 0.0]] * 8, np.float32)
        .reshape(1, 8, 1, 4), jnp.bfloat16), np.float32))
    codes, steps, y = _roundtrip_ok(x, spec)
    assert np.isfinite(np.asarray(steps)).all()
    assert np.isfinite(np.asarray(y)).all()
    # a page mixing subnormals with one normal value must keep a normal
    # step: the normal element survives, the subnormals round to zero
    # codes within the half-step bound
    x2 = x.copy()
    x2[0, 0, 0, 0] = 1.0
    codes2, steps2, _ = _roundtrip_ok(x2, spec)
    assert float(steps2[0, 0]) > 0.0
    assert bool(jnp.any(codes2.astype(jnp.float32) != 0.0))


# ---------------------------------------------------------------------------
# Write-scatter algebra
# ---------------------------------------------------------------------------

_PS, _HK, _D, _NP, _NB = 4, 2, 4, 6, 4


def _fresh_pools(spec):
    codes = jnp.zeros((_NP, _PS, _HK, _D), spec.code_dtype)
    steps = jnp.zeros((_NP, _HK), jnp.float32)
    return codes, steps


def _scatter_seq(spec, content, bt, chunk_sizes, codes=None, steps=None,
                 start=0):
    """Append ``content`` (T, HK, D) through successive chunks."""
    if codes is None:
        codes, steps = _fresh_pools(spec)
    length = start
    off = 0
    for c in chunk_sizes:
        new = jnp.zeros((1, c, _HK, _D), jnp.float32)
        new = new.at[0, :c].set(content[off:off + c])
        codes, steps = kvquant.scatter_chunk_quantized(
            codes, steps, new, bt, jnp.asarray([length], jnp.int32),
            jnp.asarray([c], jnp.int32), spec)
        length += c
        off += c
    return codes, steps


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_scatter_is_chunk_partition_invariant(spec):
    """Steps are a pure function of page content (scatter-max is
    order-free) for *every* partition; codes additionally settle bitwise
    when no page is written by more than one chunk (the partitions the
    chunked-prefill engine emits). A partition that splits a page
    double-rounds its early tokens — still within one extra quantization
    step of the single-shot encoding."""
    rng = np.random.default_rng(1)
    content = jnp.asarray(rng.normal(size=(10, _HK, _D)), jnp.float32)
    bt = jnp.asarray([[2, 0, 5, 3]], jnp.int32)
    a = _scatter_seq(spec, content, bt, [4, 4, 2])     # page-aligned
    c = _scatter_seq(spec, content, bt, [10])          # single shot
    assert bool(jnp.all(a[0] == c[0]))
    assert bool(jnp.all(a[1] == c[1]))

    b = _scatter_seq(spec, content, bt, [3, 3, 3, 1])  # splits pages
    assert bool(jnp.all(b[1] == c[1]))                 # steps still equal
    da = kvquant.dequantize_pages(a[0], a[1])
    db = kvquant.dequantize_pages(b[0], b[1])
    bound = quant.roundtrip_bound(da, a[1][..., None, :], spec)
    assert bool(jnp.all(jnp.abs(da - db) <= 2.0 * bound + 1e-30))


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_page_aligned_scatter_equals_one_shot_quantization(spec):
    """A page written by exactly one page-aligned chunk holds the same
    codes as one-shot whole-page quantization — the identity that makes
    prefill-chunked pages comparable to quantize_pages oracles."""
    rng = np.random.default_rng(2)
    content = jnp.asarray(rng.normal(size=(8, _HK, _D)), jnp.float32)
    bt = jnp.asarray([[4, 1, 0, 0]], jnp.int32)
    codes, steps = _scatter_seq(spec, content, bt, [4, 4])
    want_codes, want_steps = kvquant.quantize_pages(
        content.reshape(2, _PS, _HK, _D), spec)
    assert bool(jnp.all(codes[jnp.asarray([4, 1])] == want_codes))
    assert bool(jnp.all(steps[jnp.asarray([4, 1])] == want_steps))


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_reused_page_cannot_inherit_stale_step(spec):
    """enters-at-zero: a physical page freed by one sequence and reused by
    another (written again from its position 0) ends bitwise equal to the
    same write into a fresh pool — stale steps and codes are laundered."""
    rng = np.random.default_rng(3)
    big = jnp.asarray(rng.normal(size=(4, _HK, _D)) * 1e3, jnp.float32)
    small = jnp.asarray(rng.normal(size=(4, _HK, _D)), jnp.float32)
    bt = jnp.asarray([[2, 0, 0, 0]], jnp.int32)

    dirty = _scatter_seq(spec, big, bt, [4])              # first tenant
    codes, steps = _scatter_seq(spec, small, bt, [4],
                                codes=dirty[0], steps=dirty[1])
    fresh_codes, fresh_steps = _scatter_seq(spec, small, bt, [4])
    assert bool(jnp.all(codes[2] == fresh_codes[2]))
    assert bool(jnp.all(steps[2] == fresh_steps[2]))


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_scatter_drops_invalid_lanes(spec):
    """chunk_lens == 0 rows write nothing — pools stay bitwise."""
    codes, steps = _fresh_pools(spec)
    new = jnp.ones((1, 4, _HK, _D), jnp.float32)
    bt = jnp.asarray([[1, 0, 0, 0]], jnp.int32)
    out_codes, out_steps = kvquant.scatter_chunk_quantized(
        codes, steps, new, bt, jnp.asarray([0], jnp.int32),
        jnp.asarray([0], jnp.int32), spec)
    assert bool(jnp.all(out_codes == codes))
    assert bool(jnp.all(out_steps == steps))


# ---------------------------------------------------------------------------
# In-kernel dequant parity: Pallas kernels vs dequantized-pool oracles
# ---------------------------------------------------------------------------


def _quantized_fixture(spec, seed=0):
    """f32 pools + their quantized twins, disjoint per-row page maps."""
    rng = np.random.default_rng(seed)
    b, hq, hk, d, ps, num_pages, nb = 3, 8, 2, 64, 16, 24, 8
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(num_pages, ps, hk, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(num_pages, ps, hk, d)), jnp.float32)
    kc, ks = kvquant.quantize_pages(kp, spec)
    vc, vs = kvquant.quantize_pages(vp, spec)
    perm = rng.permutation(num_pages)
    bt = np.full((b, nb), num_pages, np.int32)
    for i in range(b):
        bt[i] = perm[i * nb:(i + 1) * nb]
    bt[2, 5:] = num_pages
    lengths = jnp.asarray([100, 37, 5 * ps], jnp.int32)
    return q, (kc, ks), (vc, vs), jnp.asarray(bt), lengths


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_paged_decode_kernels_dequantize_in_kernel(spec):
    """The decode kernels (unified-max + sync) fed quantized pools match
    the oracle run on the pool-level dequant view — the full-precision
    slab the kernels never materialize."""
    from repro.kernels.decode_attention import (
        paged_decode_attention_sync, paged_decode_attention_unified_max)
    q, (kc, ks), (vc, vs), bt, lengths = _quantized_fixture(spec)
    kd = ref.dequantize_pool_ref(kc, ks)
    vd = ref.dequantize_pool_ref(vc, vs)

    got, _ = paged_decode_attention_unified_max(
        q, kc, vc, bt, lengths, phi=0.0, k_scale=ks, v_scale=vs,
        interpret=True)
    want, _ = ref.attention_decode_paged_unified_max_ref(
        q, kd, vd, bt, lengths, phi=0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    got_s = paged_decode_attention_sync(
        q, kc, vc, bt, lengths, k_scale=ks, v_scale=vs, interpret=True)
    want_s = ref.attention_decode_paged_ref(q, kd, vd, bt, lengths)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), **TOL)


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_paged_chunk_kernels_dequantize_in_kernel(spec):
    from repro.kernels.chunk_attention import (
        paged_chunk_attention_sync, paged_chunk_attention_unified_max)
    spec_fx = _quantized_fixture(spec, seed=4)
    _, (kc, ks), (vc, vs), bt, lengths = spec_fx
    rng = np.random.default_rng(5)
    b, c, hq, d = 3, 8, 8, 64
    q = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32)
    kd = ref.dequantize_pool_ref(kc, ks)
    vd = ref.dequantize_pool_ref(vc, vs)

    got, _ = paged_chunk_attention_unified_max(
        q, kc, vc, bt, lengths, phi=0.0, k_scale=ks, v_scale=vs,
        interpret=True)
    want, _ = ref.attention_chunk_paged_fused_ref(
        q, kd, vd, bt, lengths, phi=0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    got_s = paged_chunk_attention_sync(
        q, kc, vc, bt, lengths, k_scale=ks, v_scale=vs, interpret=True)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want), **TOL)


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_grouped_kernel_dequantizes_in_kernel(spec):
    from repro.kernels.group_attention import (
        DecodeGroups, grouped_paged_decode_attention_unified_max)
    q, (kc, ks), (vc, vs), bt, lengths = _quantized_fixture(spec, seed=6)
    num_pages = kc.shape[0]
    # rows 0 and 2 share row 0's first two pages as a group prefix
    shared = np.asarray(bt)[0, :2]
    bt2 = np.asarray(bt).copy()
    bt2[2, :2] = shared
    bt2 = jnp.asarray(bt2)
    tables = np.full((1, 2), num_pages, np.int32)
    tables[0] = shared
    groups = DecodeGroups(*(jnp.asarray(a) for a in (
        tables, np.asarray([2], np.int32),
        np.asarray([32], np.int32), np.asarray([2], np.int32),
        np.asarray([[0, 2]], np.int32),
        np.asarray([0, 1, 0], np.int32),
        np.asarray([0, 0, 1], np.int32),
        np.asarray([32, 0, 32], np.int32))))
    kd = ref.dequantize_pool_ref(kc, ks)
    vd = ref.dequantize_pool_ref(vc, vs)
    got, _ = grouped_paged_decode_attention_unified_max(
        q, kc, vc, bt2, lengths, groups, phi=0.0, k_scale=ks, v_scale=vs,
        interpret=True)
    want, _ = ref.attention_decode_grouped_unified_max_ref(
        q, kd, vd, bt2, lengths, groups, phi=0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_ops_xla_path_equals_gathered_dequant(spec):
    """ops dispatch on the XLA backend: quantized pools route through the
    pool-level dequant view, bitwise-equal to gather-then-dequant."""
    from repro.kernels import ops
    q, (kc, ks), (vc, vs), bt, lengths = _quantized_fixture(spec, seed=7)
    plan = make_plan("xla")
    got = ops.attention_decode_paged(
        q, kc, vc, bt, lengths, plan=plan, k_scale=ks, v_scale=vs)
    kd = ref.dequantize_pool_ref(kc, ks)
    vd = ref.dequantize_pool_ref(vc, vs)
    want, _ = ref.attention_decode_paged_unified_max_ref(
        q, kd, vd, bt, lengths, phi=0.0)
    assert bool(jnp.all(got == want))


# ---------------------------------------------------------------------------
# COW forks copy scale rows
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_cow_fork_copies_scale_rows(spec):
    """The engine's fork is a tree-mapped page copy over *all* cache
    leaves: the forked page must get its own copy of the scale rows, and
    a later write to the fork must leave the source page's step alone."""
    rng = np.random.default_rng(8)
    content = jnp.asarray(rng.normal(size=(4, _HK, _D)), jnp.float32)
    codes, steps = _scatter_seq(spec, content, jnp.asarray([[1, 0, 0, 0]],
                                                           jnp.int32), [4])
    cache = {"k": codes[None], "k_scale": steps[None]}   # (L=1, NP, ...)

    src, dst = 1, 3
    forked = jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), cache)
    assert bool(jnp.all(forked["k"][:, dst] == cache["k"][:, src]))
    assert bool(jnp.all(forked["k_scale"][:, dst]
                        == cache["k_scale"][:, src]))

    # divergent write into the fork (bigger amax -> new step) must not
    # touch the source page's codes or step
    loud = jnp.asarray(rng.normal(size=(2, _HK, _D)) * 50.0, jnp.float32)
    new_codes, new_steps = _scatter_seq(
        spec, loud, jnp.asarray([[dst, 0, 0, 0]], jnp.int32), [2],
        codes=forked["k"][0], steps=forked["k_scale"][0], start=2)
    assert bool(jnp.all(new_codes[src] == cache["k"][0, src]))
    assert bool(jnp.all(new_steps[src] == cache["k_scale"][0, src]))
    assert not bool(jnp.all(new_steps[dst] == cache["k_scale"][0, src]))


# ---------------------------------------------------------------------------
# Engine-level guard: int8 decode logits vs bf16 across paged kernel paths
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    from repro.models.api import get_model
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, api, params


_PAGE = 16


def _mk_engine(cfg, params, kv_dtype, *, prefill_mode="gather",
               sharing=False, host_pages=None, decode_group="off"):
    from repro.serving.engine import Engine
    plan = make_plan(
        "xla",
        gather_chunk="fused" if prefill_mode == "fused" else "dense",
        fused_threshold=1,
        decode_group=decode_group, group_threshold=2,
        kv_dtype=kv_dtype or "bf16")
    return Engine(cfg, params, num_slots=3, max_seq=128,
                  cache_kind="paged", page_size=_PAGE,
                  prefill_chunk=_PAGE, plan=plan, kv_dtype=kv_dtype,
                  # the tiered store rides on the prefix index
                  prefix_sharing=sharing or bool(host_pages),
                  host_pages=host_pages,
                  session_cache=bool(host_pages) or None, seed=0)


def _prompts(cfg, sharing):
    rng = np.random.default_rng(11)
    if sharing:
        head = rng.integers(1, cfg.vocab_size, size=32).astype(np.int32)
        return [np.concatenate([head, rng.integers(
            1, cfg.vocab_size, size=_PAGE).astype(np.int32)])
            for _ in range(3)]
    return [rng.integers(1, cfg.vocab_size, size=48).astype(np.int32)
            for _ in range(3)]


def _prefill_and_probe(eng, api, prompts, *, tier_roundtrip=False):
    """Admit+prefill only (no free-running decode, so the written KV is
    exactly the prompts — dense-equivalent across kv_dtypes), then probe
    one decode step's logits through the engine's own plan."""
    from repro.models.layers import LayerCtx
    from repro.serving.request import SamplingParams
    sp = SamplingParams(max_new_tokens=4, temperature=0.0)
    if tier_roundtrip:
        eng.run([(p.copy(), sp) for p in prompts])
        eng.evict_finished(flush=True)
        assert eng.tiers.host_used > 0
    for p in prompts:
        eng.submit(p.copy(), sp)
    eng._admit()
    assert len(eng.by_slot) == len(prompts)
    if tier_roundtrip:
        assert eng.stats.promoted_pages > 0, "rerun did not promote"
    rows = sorted(eng.by_slot)
    ctx = LayerCtx(cfg=eng.cfg, plan=eng.plan)
    toks = jnp.arange(1, eng.num_slots + 1, dtype=jnp.int32)
    logits, _ = api.decode_step(
        ctx, eng.params, toks, eng.cache,
        jnp.asarray(eng.slots.lengths(), jnp.int32),
        block_tables=eng.slots.block_tables())
    return np.asarray(logits, np.float32)[rows]


@pytest.mark.parametrize("tiers", [False, True], ids=["", "tiers"])
@pytest.mark.parametrize("sharing", [False, True], ids=["", "shared"])
@pytest.mark.parametrize("prefill_mode", ["gather", "fused"])
def test_int8_decode_logits_within_guard(smoke_model, prefill_mode,
                                         sharing, tiers):
    """Greedy-decode logits under kv_dtype=int8 stay within the
    dtype-derived guard vs the bf16 baseline, for prompts whose written
    KV is identical across precisions — covering {gather, fused} prefill
    x {sharing on/off} x {cold, tier round-trip}."""
    cfg, api, params = smoke_model
    prompts = _prompts(cfg, sharing)
    out = {}
    for kd in ("bf16", "int8"):
        eng = _mk_engine(cfg, params, kd, prefill_mode=prefill_mode,
                         sharing=sharing,
                         host_pages=64 if tiers else None)
        out[kd] = _prefill_and_probe(eng, api, prompts,
                                     tier_roundtrip=tiers)
    scale = float(np.abs(out["bf16"]).max())
    atol = quant.logits_guard_tol(quant.INT8) * max(scale, 1.0)
    np.testing.assert_allclose(out["int8"], out["bf16"], atol=atol, rtol=0)


def test_int8_grouped_probe_matches_ungrouped(smoke_model):
    """The grouped-decode path under int8: a full greedy run with
    decode_group=grouped produces bitwise-identical tokens to the same
    int8 run ungrouped (the grouped XLA path reconstructs the identical
    dense view through the group plan), and the sharing run actually
    forked pages — scale rows forked with them."""
    from repro.serving.request import SamplingParams
    cfg, api, params = smoke_model
    # fully identical page-aligned prompt, staged: the leader prefills and
    # commits its pages first, then the fully-covered followers arrive and
    # their final-chunk re-run must COW-fork the shared tail page
    rng = np.random.default_rng(12)
    prompt = rng.integers(1, cfg.vocab_size, size=2 * _PAGE).astype(np.int32)
    sp = SamplingParams(max_new_tokens=6, temperature=0.0)
    outs, forks = {}, {}
    for mode in ("off", "grouped"):
        eng = _mk_engine(cfg, params, "int8", sharing=True,
                         decode_group=mode)
        ra = eng.submit(prompt.copy(), sp)
        eng.step()            # leader prefills + commits, stays resident
        rb = eng.submit(prompt.copy(), sp)
        rc = eng.submit(prompt.copy(), sp)
        while not all(eng.requests[r].finished for r in (ra, rb, rc)):
            eng.step()
        outs[mode] = [eng.requests[r].tokens for r in (ra, rb, rc)]
        forks[mode] = eng.stats.cow_forks
        if mode == "grouped":
            assert eng.stats.grouped_requests > 0, \
                "grouped path never engaged"
    assert outs["grouped"] == outs["off"]
    assert min(forks.values()) > 0, "workload produced no COW forks"


def test_int8_greedy_identical_across_paged_modes(smoke_model):
    """At a fixed write history the quantized representation is a pure
    function of page content, so int8 greedy tokens are bitwise identical
    across {gather, fused} x {sharing on/off} x tier round-trip."""
    from repro.serving.request import SamplingParams
    cfg, api, params = smoke_model
    prompts = _prompts(cfg, sharing=True)
    sp = SamplingParams(max_new_tokens=5, temperature=0.0)

    def run(**kw):
        eng = _mk_engine(cfg, params, "int8", **kw)
        rounds = 2 if kw.get("host_pages") else 1
        for r in range(rounds):
            out = eng.run([(p.copy(), sp) for p in prompts])
            if r + 1 < rounds:
                eng.evict_finished(flush=True)
        if kw.get("host_pages"):
            assert eng.stats.promoted_pages > 0
        # key by submission order, not request id (the tier round-trip's
        # second round gets fresh ids)
        return [out[k] for k in sorted(out)]

    base = run(prefill_mode="gather", sharing=False)
    assert run(prefill_mode="fused", sharing=False) == base
    assert run(prefill_mode="gather", sharing=True) == base
    assert run(prefill_mode="fused", sharing=True) == base
    assert run(prefill_mode="gather", sharing=True, host_pages=64) == base


# ---------------------------------------------------------------------------
# Engine plumbing: knob validation + byte counters
# ---------------------------------------------------------------------------


def test_engine_rejects_bad_kv_dtype_combos(smoke_model):
    from repro.serving.engine import Engine
    cfg, _, params = smoke_model
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, params, num_slots=2, max_seq=64, cache_kind="dense",
               kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        Engine(cfg, params, num_slots=2, max_seq=64, cache_kind="paged",
               page_size=_PAGE, kv_dtype="int4")


def test_engine_adopts_plan_kv_dtype_and_counts_bytes(smoke_model):
    """kv_dtype=None adopts the plan's paged.kv_dtype; the stats counters
    report the true (scale-row-inclusive) per-page bytes and accumulate
    decode reads."""
    from repro.serving.request import SamplingParams
    cfg, api, params = smoke_model
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)
               for _ in range(2)]
    sp = SamplingParams(max_new_tokens=3, temperature=0.0)
    got = {}
    for kd in ("bf16", "int8"):
        eng = _mk_engine(cfg, params, None if kd == "bf16" else kd)
        if kd == "bf16":
            assert eng.kv_dtype == "bf16"     # adopted from the plan
        eng.run([(p.copy(), sp) for p in prompts])
        assert eng.stats.kv_bytes_decode_read > 0
        got[kd] = eng.stats
        # quantized leaves exist iff quantized
        assert kvquant.cache_is_quantized(eng.cache) == (kd == "int8")
    ratio = got["bf16"].kv_page_bytes / got["int8"].kv_page_bytes
    assert ratio >= 1.9
    assert (got["bf16"].kv_bytes_decode_read
            > got["int8"].kv_bytes_decode_read)


def test_quant_bench_smoke(tmp_path, monkeypatch):
    """benchmarks.kv_quant --quick emits a well-formed artifact whose
    assertions (>=1.9x bytes + capacity, guard-pass) all ran."""
    from benchmarks import kv_quant
    monkeypatch.setattr(kv_quant, "OUT_PATH",
                        str(tmp_path / "BENCH_quant.json"))
    result = kv_quant.run(quick=True)
    assert (tmp_path / "BENCH_quant.quick.json").exists()
    assert not (tmp_path / "BENCH_quant.json").exists()
    assert result["mode"] == "quick"
    by_kd = {r["kv_dtype"]: r for r in result["bytes"]}
    assert by_kd["int8"]["bytes_per_step_ratio"] >= 1.9
    assert by_kd["int8"]["capacity_ratio"] >= 1.9
    for row in result["accuracy"]:
        assert row["within_guard"]
        assert row["max_dlogits"] <= row["guard_atol"]
