"""Fused paged chunk-attention: kernel-vs-oracle sweeps (interpret mode),
the overflow-recompute fallback, the bounded-table bitwise identity the
engine's fused mode rests on, and the engine-level greedy bit-identity
guard across {dense, gather, fused} x {prefix sharing on/off} including a
preemption run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TOL
from repro import configs
from repro.core import dispatch as dsp
from repro.core.plan import PagedPlan, PlanError, make_plan, tune
from repro.kernels import ref
from repro.kernels.chunk_attention import (
    paged_chunk_attention_sync,
    paged_chunk_attention_unified_max,
)
from repro.models.api import get_model
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams


def _fixture(dtype, *, b=3, c=16, hq=8, hk=2, d=64, ps=32, num_pages=24,
             nb=6, seed=0):
    """Random pool + disjoint per-row pages, sentinel tails, and lengths
    exercising: a partial last page (37), an empty prefix (0 — the chunk
    is the whole sequence), and a chunk that straddles a page boundary
    mid-page (3*ps - c + 5)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, c, hq, d)), dtype)
    kp = jnp.asarray(rng.normal(size=(num_pages, ps, hk, d)), dtype)
    vp = jnp.asarray(rng.normal(size=(num_pages, ps, hk, d)), dtype)
    perm = rng.permutation(num_pages)
    bt = np.full((b, nb), num_pages, np.int32)
    for i in range(b):
        bt[i] = perm[i * nb:(i + 1) * nb]
    bt[2, 4:] = num_pages                       # short row: sentinel tail
    lengths = jnp.asarray([37, 0, 3 * ps - c + 5], jnp.int32)
    return q, kp, vp, jnp.asarray(bt), lengths


@pytest.mark.parametrize(
    "dtype", ["float32",
              pytest.param("bfloat16", marks=pytest.mark.slow)])
def test_fused_chunk_kernel_matches_oracles(dtype):
    """Unified-max kernel == gather oracle (allclose) and == the
    page-blocked fused oracle; the sync kernel likewise."""
    q, kp, vp, bt, lengths = _fixture(dtype)
    out, stat = paged_chunk_attention_unified_max(
        q, kp, vp, bt, lengths, phi=0.0, interpret=True)
    want = ref.attention_chunk_paged_ref(q, kp, vp, bt, lengths, phi=0.0)
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32), **TOL[dtype])
    fo, fstat = ref.attention_chunk_paged_fused_ref(
        q, kp, vp, bt, lengths, phi=0.0)
    np.testing.assert_allclose(
        out.astype(np.float32), fo.astype(np.float32), **TOL[dtype])
    assert stat.shape == fstat.shape == (q.shape[0], kp.shape[2])
    np.testing.assert_allclose(stat, fstat, rtol=1e-5, atol=1e-5)

    out_s = paged_chunk_attention_sync(q, kp, vp, bt, lengths,
                                       interpret=True)
    want_s = ref.attention_chunk_paged_ref(q, kp, vp, bt, lengths, phi=None)
    np.testing.assert_allclose(
        out_s.astype(np.float32), want_s.astype(np.float32), **TOL[dtype])


def test_fused_chunk_kernel_causal_at_chunk_boundary():
    """Chunk-local causality: query i of row b sees exactly cache
    positions <= lengths[b] + i. Checked per-row against the dense ref on
    a gathered view, with lengths crossing page boundaries both ways."""
    q, kp, vp, bt, lengths = _fixture("float32", seed=3)
    out, _ = paged_chunk_attention_unified_max(
        q, kp, vp, bt, lengths, phi=0.0, interpret=True)
    k = ref.gather_paged_kv(kp, bt)
    v = ref.gather_paged_kv(vp, bt)
    want = ref.attention_chunk_ref(q, k, v, lengths, phi=0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # a key one past the causal frontier must change nothing: perturb the
    # pool at position lengths[0] + c (first invalid key of the last row)
    c = q.shape[1]
    pos = int(lengths[0]) + c          # strictly beyond every valid key
    page, off = pos // kp.shape[1], pos % kp.shape[1]
    kp2 = kp.at[bt[0, page], off].set(1e3)
    out2, _ = paged_chunk_attention_unified_max(
        q, kp2, vp, bt, lengths, phi=0.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out2[0]))


def test_fused_chunk_partial_last_page_masks_garbage():
    """A partially filled last page: positions past lengths+i hold noise
    that must never leak into the output (write garbage there, compare
    against a pool with zeros there)."""
    q, kp, vp, bt, lengths = _fixture("float32", seed=5)
    ps = kp.shape[1]
    c = q.shape[1]
    # poison everything beyond each row's causal frontier
    kp_p, vp_p = np.array(kp), np.array(vp)
    for row in range(q.shape[0]):
        frontier = int(lengths[row]) + c
        for col in range(bt.shape[1]):
            page = int(bt[row, col])
            if page >= kp.shape[0]:
                continue
            lo = max(frontier - col * ps, 0)
            if lo < ps:
                kp_p[page, lo:] = 7e2
                vp_p[page, lo:] = -7e2
    out_clean, _ = paged_chunk_attention_unified_max(
        q, kp, vp, bt, lengths, phi=0.0, interpret=True)
    out_poison, _ = paged_chunk_attention_unified_max(
        q, jnp.asarray(kp_p), jnp.asarray(vp_p), bt, lengths, phi=0.0,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(out_clean),
                                  np.asarray(out_poison))


def test_fused_chunk_ops_overflow_falls_back_to_safe():
    """ops.attention_chunk_paged in the fused Pallas mode: a band
    overflow must recompute with the sync kernel (finite output close to
    the safe oracle); an in-band run keeps the T1 result."""
    from repro.config import SoftmaxPhiConfig
    from repro.kernels import ops
    rng = np.random.default_rng(7)
    b, c, hq, hk, d, ps, npages, nb = 2, 8, 4, 2, 32, 16, 8, 4
    kp = jnp.asarray(rng.normal(size=(npages, ps, hk, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(npages, ps, hk, d)), jnp.float32)
    bt = jnp.asarray(rng.permutation(npages).reshape(b, nb), jnp.int32)
    lens = jnp.asarray([10, 30], jnp.int32)
    plan = make_plan(backend="pallas", gather_chunk="fused")

    q_big = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32) * 50
    out = ops.attention_chunk_paged(
        q_big, kp, vp, bt, lens,
        phi_cfg=SoftmaxPhiConfig(phi=0.0, band=(-1.0, 1.0)), plan=plan)
    safe = ref.attention_chunk_paged_ref(q_big, kp, vp, bt, lens, phi=None)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(safe),
                               rtol=1e-5, atol=1e-5)

    q_small = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32) * 0.01
    out2 = ops.attention_chunk_paged(
        q_small, kp, vp, bt, lens,
        phi_cfg=SoftmaxPhiConfig(phi=0.0, band=(-40.0, 40.0)), plan=plan)
    t1 = ref.attention_chunk_paged_ref(q_small, kp, vp, bt, lens, phi=0.0)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(t1),
                               rtol=1e-5, atol=1e-5)


def test_bounded_table_is_bitwise_neutral():
    """The fused mode's XLA realization: slicing trailing table columns
    whose pages carry only causally-masked positions must leave the
    gather-path result bitwise unchanged (Engine._chunk_tables rests on
    this)."""
    q, kp, vp, bt, lengths = _fixture("float32", seed=11)
    c, ps = q.shape[1], kp.shape[1]
    bound = -(-(int(jnp.max(lengths)) + c) // ps)
    assert bound < bt.shape[1]
    for phi in (0.0, None):
        full = ref.attention_chunk_paged_ref(q, kp, vp, bt, lengths, phi=phi)
        cut = ref.attention_chunk_paged_ref(q, kp, vp, bt[:, :bound],
                                            lengths, phi=phi)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(cut))


# ---------------------------------------------------------------------------
# Plan / dispatch decisions
# ---------------------------------------------------------------------------


def test_paged_plan_chunk_knobs_validated():
    with pytest.raises(PlanError):
        PagedPlan(gather_chunk="bogus")
    with pytest.raises(PlanError):
        PagedPlan(fused_threshold=0)
    with pytest.raises(PlanError):
        PagedPlan(chunk_block=-1)


def test_tuned_plan_carries_chunk_decision_and_roundtrips():
    from repro.core.plan import ExecutionPlan
    cfg = configs.get("qwen2-0.5b")
    p = tune(cfg)
    assert p.paged.gather_chunk == "fused"
    assert p.paged.fused_threshold >= 1
    # chunk boundaries must stay on the prefix-sharing page grid
    assert 64 % p.paged.chunk_block == 0
    assert ExecutionPlan.from_json(p.to_json()) == p


def test_chunk_cost_model_fused_wins_while_table_is_sparse():
    """The decision flow's invariant: from the tuned threshold up to
    prompts a quarter of the table width, the fused path's predicted time
    stays below the dense gather's (which pays O(table width) bytes every
    step); the per-page grid bubble only catches up once the prompt
    nearly fills the table — exactly the regime where provisioning is
    dense anyway."""
    kv_dim = 128
    thr = dsp.find_fused_threshold(4096, kv_dim)
    assert thr <= 4096
    for p_len in (thr, 2 * thr, 4096 // 4):
        t_d = dsp.predict_chunk_prefill_time("dense", p_len, 4096, kv_dim)
        t_f = dsp.predict_chunk_prefill_time("fused", p_len, 4096, kv_dim)
        assert t_f < t_d
    assert dsp.find_chunk_block(4096, kv_dim, page_size=64) in (32, 64)
    with pytest.raises(ValueError):
        dsp.predict_chunk_prefill_time("bogus", 64, 4096, kv_dim)


# ---------------------------------------------------------------------------
# Engine: greedy bit-identity across {dense, gather, fused} x sharing
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, params


FUSED = make_plan(gather_chunk="fused", fused_threshold=1)


def test_engine_identity_dense_gather_fused(smoke_model):
    """Greedy tokens are identical across the dense slot cache, the paged
    dense-gather mode, and the fused mode — with prefix sharing on and
    off (shared system-prompt workload)."""
    cfg, params = smoke_model
    rng = np.random.default_rng(13)
    header = rng.integers(1, cfg.vocab_size, size=48).astype(np.int32)
    prompts = [np.concatenate([
        header, rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (9, 23, 5, 30)]

    def reqs():
        return [(p, SamplingParams(max_new_tokens=5)) for p in prompts]

    kw = dict(num_slots=4, max_seq=128, prefill_chunk=16)
    outs = {
        "dense": Engine(cfg, params, cache_kind="dense", **kw).run(reqs()),
        "gather": Engine(cfg, params, cache_kind="paged", page_size=16,
                         **kw).run(reqs()),
        "fused": Engine(cfg, params, cache_kind="paged", page_size=16,
                        plan=FUSED, **kw).run(reqs()),
        "gather+share": Engine(cfg, params, cache_kind="paged", page_size=16,
                               prefix_sharing=True, **kw).run(reqs()),
        "fused+share": Engine(cfg, params, cache_kind="paged", page_size=16,
                              plan=FUSED, prefix_sharing=True,
                              **kw).run(reqs()),
    }
    base = outs.pop("dense")
    for name, got in outs.items():
        assert got == base, f"{name} diverged from dense"


def test_engine_identity_fused_under_preemption_with_sharing(smoke_model):
    """The hard case: a sharing sequence preempted mid-decode under an
    overcommitted pool, in the fused mode — release drops refs,
    re-admission re-maps the surviving prefix and re-prefills through
    resident-bounded tables, and greedy outputs still match the gather
    mode without sharing."""
    cfg, params = smoke_model
    rng = np.random.default_rng(17)
    header = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([
        header, rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (9, 10)]

    def reqs():
        return [(p, SamplingParams(max_new_tokens=26)) for p in prompts]

    kw = dict(num_slots=2, max_seq=80, page_size=16, prefill_chunk=16,
              num_pages=5)
    fused = Engine(cfg, params, cache_kind="paged", prefix_sharing=True,
                   plan=FUSED, **kw)
    gather = Engine(cfg, params, cache_kind="paged", prefix_sharing=False,
                    **kw)
    out_f = fused.run(reqs())
    out_g = gather.run(reqs())
    assert fused.stats.preemptions > 0, "pool was never under pressure"
    assert fused.stats.shared_prefix_pages > 0, "nothing was shared"
    assert out_f == out_g
    fused.slots.check()
    assert fused.pool.used_pages == 0


def test_engine_fused_threshold_keeps_short_waves_on_gather(smoke_model):
    """Prompts below paged.fused_threshold keep the one-compile full-width
    table (the tuned inflection), and outputs still match."""
    cfg, params = smoke_model
    rng = np.random.default_rng(19)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (7, 12)]

    def reqs():
        return [(p, SamplingParams(max_new_tokens=4)) for p in prompts]

    high = make_plan(gather_chunk="fused", fused_threshold=10_000)
    kw = dict(num_slots=2, max_seq=128, page_size=16, prefill_chunk=16,
              cache_kind="paged")
    a = Engine(cfg, params, plan=high, **kw)
    b = Engine(cfg, params, **kw)
    assert a.run(reqs()) == b.run(reqs())


def test_slot_manager_lengths_device_cache(smoke_model):
    """The lengths operand is device-cached with the block-table cache's
    invalidation discipline: same buffer while nothing changed, fresh
    after assign/tick/release."""
    from repro.serving.blockpool import BlockPool, PagedSlotManager
    pool = BlockPool(16, 16)
    mgr = PagedSlotManager(4, 64, pool)
    l0 = mgr.lengths_device()
    assert mgr.lengths_device() is l0              # cached, no re-upload
    idx = mgr.try_assign(0, 10, 4)
    l1 = mgr.lengths_device()
    assert l1 is not l0 and int(l1[idx]) == 10
    assert mgr.lengths_device() is l1
    mgr.tick(idx)
    l2 = mgr.lengths_device()
    assert l2 is not l1 and int(l2[idx]) == 11
    mgr.tick(idx, wrote_kv=False)                  # no KV written: no change
    assert mgr.lengths_device() is l2
    mgr.release(idx)
    l3 = mgr.lengths_device()
    assert l3 is not l2 and int(l3[idx]) == 0
    np.testing.assert_array_equal(np.asarray(l3), mgr.lengths())


def test_prefill_buckets_are_logarithmic(smoke_model):
    """Batched single-shot prefill pads to power-of-two buckets: distinct
    tail lengths in the same bucket share one compile."""
    cfg, params = smoke_model
    from repro.models import ssm  # noqa: F401  (family without chunked path)
    scfg = configs.smoke(configs.get("rwkv6-1.6b"))
    sapi = get_model(scfg)
    sparams = sapi.init_params(jax.random.PRNGKey(1))
    eng = Engine(scfg, sparams, num_slots=2, max_seq=512)
    assert eng.prefill_chunk == 0                  # batched single-shot path
    rng = np.random.default_rng(23)
    for n in (70, 100, 120):                       # all land in the 128 bucket
        eng.run([(rng.integers(1, scfg.vocab_size, size=n).astype(np.int32),
                  SamplingParams(max_new_tokens=1))])
    assert set(eng._prefill_cache) == {128}


def test_chunk_bench_smoke(tmp_path, monkeypatch):
    """benchmarks.chunk_prefill --quick asserts cross-mode identity and
    emits a well-formed BENCH_chunk.json with the fused mode ahead."""
    from benchmarks import chunk_prefill
    monkeypatch.setattr(chunk_prefill, "OUT_PATH",
                        str(tmp_path / "BENCH_chunk.json"))
    result = chunk_prefill.run(quick=True)
    assert (tmp_path / "BENCH_chunk.quick.json").exists()
    assert not (tmp_path / "BENCH_chunk.json").exists()
    assert result["rows"]
    by_mode = {}
    for row in result["rows"]:
        assert {"prompt_len", "batch", "mode", "ttft_s",
                "kv_bytes_materialized_per_chunk",
                "bit_identical"} <= set(row)
        assert row["bit_identical"]
        by_mode.setdefault((row["prompt_len"], row["batch"]), {})[
            row["mode"]] = row
    for cell in by_mode.values():
        g, f = cell["gather"], cell["fused"]
        assert (f["kv_bytes_materialized_per_chunk"] * 2
                <= g["kv_bytes_materialized_per_chunk"])
