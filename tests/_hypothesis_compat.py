"""Hypothesis compatibility shim: use the real package when installed,
otherwise a minimal deterministic fallback.

The tier-1 suite property-tests the T1 math, the dispatch table, the
distributed combine, and the block pool. The container does not always ship
``hypothesis``, and the suite must collect and run either way, so test
modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly.

The fallback implements exactly the strategy surface the suite uses
(``integers``, ``floats``, ``sampled_from``, ``lists``, ``tuples``,
``booleans``) with a seeded ``random.Random`` per test: examples are
deterministic across runs, ``max_examples`` is honored, and the first
failing example is re-raised with the drawn arguments attached. It does no
shrinking — it is a property *runner*, not a property *search engine* —
which is the right trade for a smoke tier that must stay fast.

**Determinism contract.** The fallback is always deterministic (seeded per
test qualname). The real package randomizes its search by default, which
would make the default lane flaky-by-design, so when ``CI`` is set in the
environment every profile the suite registers is forced to
``derandomize=True`` — each run replays the same example sequence. Escape
hatch for counterexample *hunting*: run locally without ``CI``, or pass
hypothesis' builtin ``pytest --hypothesis-seed=<n>`` to pin a specific
randomized search.
"""
from __future__ import annotations

import os

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    import hypothesis.strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True

    if os.environ.get("CI"):  # pragma: no cover - CI-lane only
        _register = settings.register_profile

        def _register_derandomized(name, parent=None, **kw):
            kw.setdefault("derandomize", True)
            return _register(name, parent=parent, **kw)

        settings.register_profile = _register_derandomized
        settings.register_profile("ci", deadline=None)
        settings.load_profile("ci")
except ImportError:
    import functools
    import inspect
    import itertools
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw rule: ``draw(rng) -> value``."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self.draw(rng)))

        def filter(self, pred, _tries: int = 100):
            def draw(rng):
                for _ in range(_tries):
                    v = self.draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate never satisfied")

            return _Strategy(draw)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    st = _Strategies()

    class settings:  # noqa: N801 - mirror hypothesis' class name
        _profiles: dict = {"default": {"max_examples": 20}}
        _active: dict = _profiles["default"]

        def __init__(self, **kw):
            self._kw = kw

        def __call__(self, fn):
            fn._compat_settings = self._kw
            return fn

        @classmethod
        def register_profile(cls, name: str, **kw):
            cls._profiles[name] = kw

        @classmethod
        def load_profile(cls, name: str):
            cls._active = cls._profiles[name]

    def given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                overrides = getattr(fn, "_compat_settings", {})
                n = overrides.get(
                    "max_examples", settings._active.get("max_examples", 20))
                # the fallback is a smoke runner, not a search engine: cap
                # the example count so shape-varying draws don't turn into
                # dozens of fresh XLA compiles per property
                n = min(n, 10)
                # stable per-test seed so failures reproduce across runs
                rng = random.Random(fn.__qualname__)
                for i in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    try:
                        fn(*args, *drawn, **drawn_kw, **kwargs)
                    except Exception as e:  # noqa: BLE001
                        raise AssertionError(
                            f"property failed on example {i}: "
                            f"args={drawn!r} kwargs={drawn_kw!r}"
                        ) from e

            # Hide the drawn parameters from pytest (it would otherwise
            # try to resolve them as fixtures). Hypothesis binds positional
            # strategies to the RIGHTMOST params; anything left over is a
            # real fixture and stays visible.
            params = list(inspect.signature(fn).parameters.values())
            keep = params[:len(params) - len(strategies)]
            keep = [p for p in keep if p.name not in kw_strategies]
            wrapper.__signature__ = inspect.Signature(keep)
            del wrapper.__wrapped__  # pytest must not unwrap to fn
            # counter keeps pytest from deduping parametrized wrappers
            wrapper._compat_id = next(_COUNTER)
            return wrapper

        return deco

    _COUNTER = itertools.count()
