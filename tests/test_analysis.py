"""Roofline/HLO analysis unit tests: the collective parser against
synthetic HLO, the linear L-decomposition, and bottleneck attribution."""
import numpy as np

from repro.analysis import hlo, roofline

SYNTH_HLO = """
HloModule jit_step

%fused_add (a: f32[8,128]) -> f32[8,128] {
  ROOT %r = f32[8,128] parameter(0)
}

%while_body_1 (arg: (f32[4,4])) -> (f32[4,4]) {
  %p = f32[4,4] parameter(0)
  %ar = f32[4,4]{1,0} all-reduce(%p), replica_groups={}
  ROOT %t = (f32[4,4]) tuple(%ar)
}

ENTRY %main (x: bf16[16,256]) -> bf16[16,256] {
  %x = bf16[16,256] parameter(0)
  %ag = bf16[32,256]{1,0} all-gather(%x), dimensions={0}
  %ar2 = f32[16,256]{1,0} all-reduce-start(%x), replica_groups={}
  %ar2d = f32[16,256]{1,0} all-reduce-done(%ar2)
  %rs = bf16[8,256]{1,0} reduce-scatter(%x), dimensions={0}
  %cp = bf16[16,256]{1,0} collective-permute(%x)
  ROOT %out = bf16[16,256] add(%x, %x)
}
"""


def test_parse_collectives_counts_and_bytes():
    stats = hlo.parse_collectives(SYNTH_HLO)
    kinds = stats.by_kind()
    assert kinds["all-gather"] == 32 * 256 * 2
    # async pair counted once (start only)
    assert kinds["all-reduce"] == 16 * 256 * 4 + 4 * 4 * 4
    assert kinds["reduce-scatter"] == 8 * 256 * 2
    assert kinds["collective-permute"] == 16 * 256 * 2
    assert stats.counts["all-gather"] == 1


def test_parse_collectives_while_multiplier():
    stats = hlo.parse_collectives(SYNTH_HLO)
    base = stats.total_bytes()
    boosted = stats.total_bytes({"while": 10})
    assert boosted - base == 9 * (4 * 4 * 4)  # only the while-body AR scales


def test_linear_extrapolation_exact():
    probes = [
        roofline.ProbeCost(1, flops=100.0, bytes_accessed=50.0,
                           collective_bytes=7.0),
        roofline.ProbeCost(3, flops=160.0, bytes_accessed=90.0,
                           collective_bytes=13.0),
    ]
    full = roofline.extrapolate(probes, 10)
    # per-layer: 30 flops, 20 bytes, 3 coll; outside: 70, 30, 4
    np.testing.assert_allclose(full.flops, 70 + 300)
    np.testing.assert_allclose(full.bytes_accessed, 30 + 200)
    np.testing.assert_allclose(full.collective_bytes, 4 + 30)


def test_terms_bottleneck_attribution():
    cost = roofline.ProbeCost(1, flops=1e15, bytes_accessed=1e9,
                              collective_bytes=1e6)
    t = roofline.terms_from(arch="a", shape="s", mesh="16x16", chips=256,
                            cost=cost, model_flops=5e14)
    assert t.bottleneck == "compute"
    assert abs(t.useful_ratio - 0.5) < 1e-9
    cost = roofline.ProbeCost(1, flops=1e9, bytes_accessed=1e9,
                              collective_bytes=1e12)
    t = roofline.terms_from(arch="a", shape="s", mesh="16x16", chips=256,
                            cost=cost, model_flops=1e9)
    assert t.bottleneck == "collective"
    assert t.bound_s == t.collective_s


def test_model_flops_train_vs_decode():
    train = roofline.model_flops_estimate(
        params_active=int(1e9), tokens=1000, kind="train")
    decode = roofline.model_flops_estimate(
        params_active=int(1e9), tokens=1000, kind="decode")
    assert abs(train / decode - 3.0) < 1e-9  # 6ND vs 2ND


def test_format_table_runs():
    cost = roofline.ProbeCost(1, 1e12, 1e10, 1e8)
    t = roofline.terms_from(arch="qwen2-0.5b", shape="train_4k",
                            mesh="16x16", chips=256, cost=cost,
                            model_flops=5e11, per_device_bytes=int(2e9))
    out = roofline.format_table([t.to_dict()])
    assert "qwen2-0.5b" in out and "compute" in out
