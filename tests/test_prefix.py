"""Prefix sharing: chain-hash index semantics, refcount/COW invariants
under random lifecycles (property-based via the hypothesis shim), and the
acceptance bar — greedy outputs bit-identical with sharing on vs off
(vs the dense engine too), including across a preemption of a sharing
sequence and through the fully-covered COW-fork path."""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import configs
from repro.models.api import get_model
from repro.models.kvlayout import pages_for
from repro.serving.blockpool import BlockPool, PagedSlotManager
from repro.serving.engine import Engine
from repro.serving.prefix import PrefixIndex
from repro.serving.request import SamplingParams
from repro.serving.tiers import TieredPool

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


# ---------------------------------------------------------------------------
# PrefixIndex unit semantics
# ---------------------------------------------------------------------------


def test_index_matches_only_full_page_aligned_prefixes():
    ix = PrefixIndex(page_size=4)
    toks = list(range(10, 21))                 # 11 tokens = 2 full pages
    assert ix.register(toks, pages=[7, 8, 99]) == 2   # tail page ignored
    m = ix.match(toks)
    assert m.pages == [7, 8]
    assert ix.match(toks[:7]).pages == [7]     # 1 full page covered
    assert ix.match(toks[:3]).pages == []      # below one page: no match
    assert len(ix) == 2


def test_index_chain_hash_requires_matching_ancestry():
    ix = PrefixIndex(page_size=4)
    ix.register([1, 2, 3, 4, 5, 6, 7, 8], pages=[0, 1])
    # same second chunk, different first chunk -> chain key differs, and
    # the match must stop at the first divergent page
    m = ix.match([9, 9, 9, 9, 5, 6, 7, 8])
    assert m.pages == []
    m = ix.match([1, 2, 3, 4, 9, 9, 9, 9])
    assert m.pages == [0]


def test_index_first_registrant_wins_and_drop_purges():
    ix = PrefixIndex(page_size=2)
    ix.register([1, 2, 3, 4], pages=[5, 6])
    ix.register([1, 2, 9, 9], pages=[7, 8])    # chunk [1,2] already indexed
    assert ix.match([1, 2]).pages == [5]
    assert ix.match([1, 2, 9, 9]).pages == [5, 8]
    ix.drop_page(5)                            # page returned to free list
    assert ix.match([1, 2, 3, 4]).pages == []  # chain broken at the root
    assert 5 not in ix.shared_page_ids()
    ix.check(live_pages={6, 8})


def test_index_pending_levels_and_commit():
    ix = PrefixIndex(page_size=2)
    ix.register([1, 2, 3, 4], pages=[0, 1], level=0)   # promised, unwritten
    m = ix.match([1, 2, 3, 4, 5])
    assert m.pages == [0, 1]
    assert m.pending_level == 0 and m.tail_pending
    ix.commit([1, 2, 3, 4])
    m = ix.match([1, 2, 3, 4, 5])
    assert m.pending_level == -1 and not m.tail_pending


# ---------------------------------------------------------------------------
# Refcounted manager + COW fork: directed and property-based lifecycles
# ---------------------------------------------------------------------------


def _mgr(num_pages=16, page_size=4, num_slots=3, max_seq=32):
    pool = BlockPool(num_pages, page_size)
    return PagedSlotManager(num_slots, max_seq, pool,
                            prefix_index=PrefixIndex(page_size)), pool


def test_shared_admission_bumps_refcounts_and_skips_pages():
    mgr, pool = _mgr()
    toks = np.arange(100, 109, dtype=np.int32)          # 9 tokens, 2 full pages
    a = mgr.try_assign(0, 9, 4, tokens=toks)
    assert a is not None
    mgr.commit_prefix(a, toks)
    used_before = pool.used_pages
    b = mgr.try_assign(1, 9, 4, tokens=toks)
    assert b is not None
    sb = mgr.slots[b]
    assert sb.shared_len == 8 and sb.prefill_start == 8
    assert sb.pages[:2] == mgr.slots[a].pages[:2]       # same physical pages
    assert all(pool.refcount(p) == 2 for p in sb.pages[:2])
    # only the tail + headroom were newly allocated
    assert pool.used_pages == used_before + (len(sb.pages) - 2)
    mgr.check()


def test_shared_pages_survive_one_owners_release():
    mgr, pool = _mgr()
    toks = np.arange(100, 109, dtype=np.int32)
    a = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(a, toks)
    b = mgr.try_assign(1, 9, 4, tokens=toks)
    shared = list(mgr.slots[b].pages[:2])
    mgr.release(a)                                      # victim lets go
    assert all(pool.refcount(p) == 1 for p in shared)   # survived via b
    assert mgr.prefix.match(toks).pages == shared       # still matchable
    mgr.release(b)                                      # last owner
    assert all(pool.refcount(p) == 0 for p in shared)
    assert mgr.prefix.match(toks).pages == []           # purged with pages
    mgr.check()
    assert pool.free_pages == pool.num_pages


def test_fork_for_write_privatizes_without_aliasing():
    mgr, pool = _mgr()
    toks = np.arange(100, 109, dtype=np.int32)
    a = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(a, toks)
    b = mgr.try_assign(1, 9, 4, tokens=toks)
    shared = list(mgr.slots[b].pages)
    forks = mgr.fork_for_write(b, 0, 9)        # write over the shared span
    assert forks is not None and len(forks) == 2
    for src, dst in forks:
        assert src != dst
        assert pool.refcount(src) == 1         # back to a's exclusively
        assert pool.refcount(dst) == 1         # b's private copy
        assert dst in mgr.slots[b].pages and dst not in mgr.slots[a].pages
    assert mgr.fork_for_write(b, 0, 9) == []   # idempotent: all private now
    assert shared[2:] == mgr.slots[b].pages[2:]  # unshared tail untouched
    mgr.check()


def test_fork_for_write_reports_dry_pool():
    pool = BlockPool(num_pages=4, page_size=4)
    mgr = PagedSlotManager(3, 16, pool, prefix_index=PrefixIndex(4))
    toks = np.arange(50, 55, dtype=np.int32)            # 5 toks: 1 full page
    a = mgr.try_assign(0, 5, 1, tokens=toks)            # 2 pages
    mgr.commit_prefix(a, toks)
    b = mgr.try_assign(1, 5, 1, tokens=toks)            # shares 1, allocs 1
    c = mgr.try_assign(2, 1, 1)                         # takes the last page
    assert b is not None and c is not None
    assert pool.free_pages == 0
    assert mgr.fork_for_write(b, 0, 4) is None          # dry: caller preempts
    mgr.check()                                         # nothing corrupted
    mgr.release(c)                                      # preemption mechanics
    forks = mgr.fork_for_write(b, 0, 4)                 # retry succeeds,
    assert forks and pool.refcount(forks[0][0]) == 1    # page still shared
    mgr.check()


def test_fork_for_write_rolls_back_partial_forks_on_dry_pool():
    """A multi-page fork that runs dry mid-way must undo the forks it
    already made (table restored, ref re-taken, destination freed) — a
    fork left patched-but-uncopied would read uninitialized KV after the
    caller's preempt-and-retry skips the now-refcount-1 page."""
    pool = BlockPool(num_pages=7, page_size=4)
    mgr = PagedSlotManager(3, 16, pool, prefix_index=PrefixIndex(4))
    toks = np.arange(60, 69, dtype=np.int32)            # 9 toks: 2 full pages
    a = mgr.try_assign(0, 9, 1, tokens=toks)            # 3 pages
    mgr.commit_prefix(a, toks)
    b = mgr.try_assign(1, 9, 1, tokens=toks)            # shares 2, allocs 1
    c = mgr.try_assign(2, 5, 1)                         # takes 2 more
    assert b is not None and c is not None
    assert pool.free_pages == 1                         # room for ONE fork
    before = list(mgr.slots[b].pages)
    assert mgr.fork_for_write(b, 0, 8) is None          # second fork dry
    assert mgr.slots[b].pages == before                 # rolled back
    assert all(pool.refcount(p) == 2 for p in before[:2])
    assert pool.free_pages == 1
    mgr.check()
    mgr.release(c)                                      # preempt-and-retry
    forks = mgr.fork_for_write(b, 0, 8)
    assert forks is not None and len(forks) == 2        # both pages forked
    mgr.check()


def _assert_group_plan_consistent(mgr):
    """Decode-group plan invariants, checked against the manager's own
    ground truth after every lifecycle op:

      * every resident slot is in exactly one group or solo, and the solo
        sentinel is coherent (``gid == NG`` iff ``prefix_len == 0``);
      * each group's table is exactly its members' leading pages and
        every one of those pages is genuinely shared (refcount >= 2);
      * ``member_rows`` round-trips ``gid``/``member`` (the kernel's
        scatter and un-scatter agree on who sits where);
      * no member is grouped beyond its valid KV
        (``length >= prefix_len``).
    """
    plan = mgr.group_plan(threshold=2)
    if plan is None:
        return
    ng = plan.tables.shape[0]
    grouped_rows = set()
    for g in range(ng):
        nm = int(plan.num_members[g])
        if nm == 0:
            continue
        assert nm >= 2, "a 1-member group saves nothing"
        key = [int(p) for p in plan.tables[g, :int(plan.n_pages[g])]]
        assert key and all(mgr.pool.refcount(p) >= 2 for p in key)
        plen = int(plan.g_prefix_len[g])
        assert plen == len(key) * mgr.pool.page_size
        rows = [int(r) for r in plan.member_rows[g, :nm]]
        assert len(set(rows)) == nm, "member row listed twice"
        for r, i in enumerate(rows):
            s = mgr.slots[i]
            assert not s.free and i not in grouped_rows
            grouped_rows.add(i)
            assert list(s.pages[:len(key)]) == key
            assert s.length >= plen
            assert int(plan.gid[i]) == g and int(plan.member[i]) == r
            assert int(plan.prefix_len[i]) == plen
    for i in range(len(mgr.slots)):
        if i in grouped_rows:
            continue
        assert int(plan.gid[i]) == ng      # solo sentinel
        assert int(plan.prefix_len[i]) == 0


@given(st.integers(0, 10_000))
def test_sharing_manager_random_lifecycle(seed):
    """check() invariants — refcount == ownership multiset, no page both
    free and owned, fork never aliases, index maps only live pages —
    under random admit(shared-prefix tokens)/grow/fork/commit/release;
    plus the decode-group plan invariants after every op (the plan is
    rebuilt from live refcounts, so fork/release must re-key it)."""
    rng = np.random.default_rng(seed)
    page_size = int(rng.choice([2, 4]))
    num_pages = int(rng.integers(6, 32))
    num_slots = int(rng.integers(2, 5))
    max_seq = page_size * max(3, num_pages // num_slots)
    pool = BlockPool(num_pages, page_size)
    mgr = PagedSlotManager(num_slots, max_seq, pool,
                           prefix_index=PrefixIndex(page_size))
    # a tiny prompt pool with heavy prefix overlap: every prompt extends
    # one of two headers, so admissions genuinely share pages
    headers = [list(rng.integers(1, 50, size=2 * page_size)) for _ in range(2)]
    live: dict[int, np.ndarray] = {}
    rid = 0
    for _ in range(50):
        op = rng.random()
        if op < 0.4:
            toks = np.asarray(
                headers[int(rng.integers(2))][:int(rng.integers(
                    1, 2 * page_size + 1))]
                + list(rng.integers(1, 50, size=int(rng.integers(0, 6)))),
                np.int32)[:max_seq - 1]
            max_new = int(rng.integers(1, max_seq - len(toks) + 1))
            if pages_for(len(toks) + max_new, page_size) > num_pages:
                continue
            idx = mgr.try_assign(rid, len(toks), max_new, tokens=toks)
            if idx is not None:
                assert idx not in live, "slot double-assigned"
                live[idx] = toks
                rid += 1
                mgr.commit_prefix(idx, toks)   # content "written"
        elif op < 0.55 and live:
            idx = list(live)[rng.integers(len(live))]
            mgr.ensure(idx, int(rng.integers(1, max_seq + 1)))
        elif op < 0.75 and live:
            idx = list(live)[rng.integers(len(live))]
            pos = int(rng.integers(0, max_seq))
            mgr.fork_for_write(idx, pos, pos + 1)   # dry-pool None is fine
        elif live:
            idx = list(live)[rng.integers(len(live))]
            del live[idx]
            mgr.release(idx)
        mgr.check()                           # invariants after every op
        _assert_group_plan_consistent(mgr)
    for idx in list(live):
        mgr.release(idx)
    mgr.check()
    assert pool.free_pages == num_pages       # every ref returned
    assert len(mgr.prefix) == 0               # index died with its pages
    assert mgr.group_plan(threshold=2) is None  # nothing resident to group


@given(st.integers(0, 10_000))
def test_tiered_manager_random_lifecycle(seed):
    """The same random-lifecycle invariants with a tiered store behind
    the pool, plus the cross-tier ops: retire-with-retention
    (retain_session), demotion under pressure (reclaim_session with a
    dummy gather), promotion at re-admission (overlapping prompts re-hit
    demoted entries whenever the random swap_threshold allows), and true
    eviction off a deliberately tiny host tier. After every op:
    refcounts == slot+session ownership, every demoted index entry
    resolves to a live slab, tier capacities respected."""
    rng = np.random.default_rng(seed)
    page_size = int(rng.choice([2, 4]))
    num_pages = int(rng.integers(6, 24))
    num_slots = int(rng.integers(2, 5))
    host_pages = int(rng.integers(0, 6))      # 0 = evict-on-demote hierarchy
    max_seq = page_size * max(3, num_pages // num_slots)
    pool = BlockPool(num_pages, page_size)
    ix = PrefixIndex(page_size)
    tiers = TieredPool(host_pages, index=ix)
    mgr = PagedSlotManager(num_slots, max_seq, pool,
                           prefix_index=ix, tiers=tiers)
    mgr.swap_threshold = int(rng.integers(1, 4))

    def gather(pages):                        # engine's device→host stand-in
        return {p: ("slab", p) for p in pages}

    if rng.random() < 0.5:                    # engine wiring: cache loses
        mgr.reclaim_cb = \
            lambda need: mgr.reclaim_session(need, gather) >= need
    headers = [list(rng.integers(1, 50, size=2 * page_size)) for _ in range(2)]
    live: dict[int, np.ndarray] = {}
    rid = 0
    for _ in range(50):
        op = rng.random()
        if op < 0.35:
            toks = np.asarray(
                headers[int(rng.integers(2))][:int(rng.integers(
                    1, 2 * page_size + 1))]
                + list(rng.integers(1, 50, size=int(rng.integers(0, 6)))),
                np.int32)[:max_seq - 1]
            max_new = int(rng.integers(1, max_seq - len(toks) + 1))
            if pages_for(len(toks) + max_new, page_size) > num_pages:
                continue
            idx = mgr.try_assign(rid, len(toks), max_new, tokens=toks)
            if idx is not None:
                live[idx] = toks
                rid += 1
                mgr.commit_prefix(idx, toks)
        elif op < 0.45 and live:
            idx = list(live)[rng.integers(len(live))]
            mgr.ensure(idx, int(rng.integers(1, max_seq + 1)))
        elif op < 0.55 and live:
            idx = list(live)[rng.integers(len(live))]
            pos = int(rng.integers(0, max_seq))
            mgr.fork_for_write(idx, pos, pos + 1)
        elif op < 0.70 and live:              # retire into the session cache
            idx = list(live)[rng.integers(len(live))]
            mgr.retain_session(idx, live.pop(idx))
        elif op < 0.80:                       # pool pressure: demote LRU
            mgr.reclaim_session(int(rng.integers(1, 4)), gather)
        elif live:
            idx = list(live)[rng.integers(len(live))]
            del live[idx]
            mgr.release(idx)
        mgr.check()                           # cross-tier invariants
        _assert_group_plan_consistent(mgr)
    for idx in list(live):
        mgr.release(idx)
    mgr.reclaim_session(num_pages, gather)    # drain the session cache
    mgr.check()
    assert pool.free_pages == num_pages       # tier 0 fully reclaimed
    # whatever keys remain are demoted — every one resolves to a live slab
    assert len(ix) == len(ix.demoted_ids())
    assert ix.demoted_ids() <= tiers.ids()
    assert len(tiers) <= host_pages


# ---------------------------------------------------------------------------
# Engine: greedy outputs are bit-identical with sharing on vs off
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, *, sharing, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_seq", 128)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("page_size", 16)
    return Engine(cfg, params, cache_kind="paged",
                  prefix_sharing=sharing, **kw)


def test_shared_prefix_batch_identical_and_cheaper(smoke_model):
    """The acceptance bar: a batch sharing a (page-aligned-or-not) system
    prompt produces bit-identical greedy tokens with sharing on vs off
    AND vs the dense engine, while allocating fewer pages and skipping
    the shared prefill positions."""
    cfg, params = smoke_model
    rng = np.random.default_rng(3)
    header = rng.integers(1, cfg.vocab_size, size=40).astype(np.int32)
    prompts = [np.concatenate([
        header, rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (9, 23, 5, 17)]

    def reqs():
        return [(p, SamplingParams(max_new_tokens=4)) for p in prompts]

    on = _engine(cfg, params, sharing=True)
    off = _engine(cfg, params, sharing=False)
    dense = Engine(cfg, params, cache_kind="dense", num_slots=4,
                   max_seq=128, prefill_chunk=16)
    out_on = on.run(reqs())
    assert out_on == off.run(reqs()) == dense.run(reqs())
    # 40-token header = 2 full 16-token pages shared by 3 followers
    assert on.stats.shared_prefix_pages == 6
    assert on.stats.saved_prefill_tokens == 6 * 16
    assert on.stats.peak_pages_used < off.stats.peak_pages_used
    on.slots.check()
    assert on.pool.used_pages == 0 and len(on.prefix) == 0  # drained


def test_fully_covered_prompt_cow_forks_and_matches(smoke_model):
    """A later request whose page-aligned prompt is FULLY resident must
    fork the tail page (the final-chunk re-run that recovers last-token
    logits writes into a refcount-2 page) and still match sharing-off
    outputs exactly."""
    cfg, params = smoke_model
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, cfg.vocab_size, size=32).astype(np.int32)
    outs = {}
    for sharing in (True, False):
        eng = _engine(cfg, params, sharing=sharing, num_slots=2)
        ra = eng.submit(prompt, SamplingParams(max_new_tokens=8))
        eng.step()            # a prefills + commits, stays resident
        rb = eng.submit(prompt, SamplingParams(max_new_tokens=8))
        while not (eng.requests[ra].finished and eng.requests[rb].finished):
            eng.step()
        outs[sharing] = {r: eng.requests[r].tokens for r in (ra, rb)}
        if sharing:
            assert eng.stats.cow_forks == 1
            assert eng.stats.shared_prefix_pages == 1   # fork dst is private
            eng.slots.check()
    assert outs[True] == outs[False]


def test_preempted_sharing_sequence_identical(smoke_model):
    """Preemption of a *sharing* sequence: its release only drops refs
    (the shared page survives through the leader), re-admission re-maps
    the surviving prefix, and greedy outputs still match a sharing-off
    run bit-exactly."""
    cfg, params = smoke_model
    rng = np.random.default_rng(7)
    header = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([
        header, rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (9, 10)]

    def reqs():
        return [(p, SamplingParams(max_new_tokens=26)) for p in prompts]

    kw = dict(num_slots=2, max_seq=80, page_size=16, prefill_chunk=16,
              num_pages=5)
    on = _engine(cfg, params, sharing=True, **kw)
    off = _engine(cfg, params, sharing=False, **kw)
    out_on = on.run(reqs())
    out_off = off.run(reqs())
    assert on.stats.preemptions > 0, "pool was never under pressure"
    assert on.stats.shared_prefix_pages > 0, "nothing was shared"
    assert out_on == out_off
    assert any(on.requests[r].preemptions > 0 for r in out_on)
    on.slots.check()
    assert on.pool.used_pages == 0             # every ref returned


def test_sharing_survives_waves_and_recycling(smoke_model):
    """More requests than slots: later admission waves must match the
    index only while the pages are alive, recycle dead pages safely, and
    stay bit-identical to sharing-off."""
    cfg, params = smoke_model
    rng = np.random.default_rng(11)
    header = rng.integers(1, cfg.vocab_size, size=32).astype(np.int32)
    prompts = [np.concatenate([
        header, rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (3, 19, 8, 27, 12)]

    def reqs():
        return [(p, SamplingParams(max_new_tokens=6)) for p in prompts]

    kw = dict(num_slots=2, max_seq=128)
    on = _engine(cfg, params, sharing=True, **kw)
    off = _engine(cfg, params, sharing=False, **kw)
    assert on.run(reqs()) == off.run(reqs())
    assert on.stats.shared_prefix_pages > 0
    on.slots.check()
    assert on.pool.used_pages == 0 and len(on.prefix) == 0


def test_victim_signal_tracks_live_refcounts(smoke_model):
    """exclusive_len must reflect refcounts at eviction time, not at
    admission: when the leader finishes, its follower becomes the sole
    owner of the once-shared pages and must stop looking cheap to
    evict."""
    cfg, params = smoke_model
    rng = np.random.default_rng(13)
    prompt = rng.integers(1, cfg.vocab_size, size=33).astype(np.int32)
    eng = _engine(cfg, params, sharing=True, num_slots=2)
    ra = eng.submit(prompt, SamplingParams(max_new_tokens=20))
    eng.step()
    rb = eng.submit(prompt, SamplingParams(max_new_tokens=20))
    eng.step()
    a, b = eng.requests[ra], eng.requests[rb]
    eng._refresh_shared_lens()
    assert b.shared_len == 32                 # 2 shared 16-token pages
    assert a.shared_len == 32                 # leader's copy is shared too
    eng.abort(ra)                             # leader gone: b sole owner
    eng._refresh_shared_lens()
    assert b.shared_len == 0                  # nothing shared anymore
    assert b.exclusive_len == b.total_len     # eviction reclaims it all


def test_prefix_bench_smoke(tmp_path, monkeypatch):
    """CI wiring: the prefix-sharing sweep runs at smoke sizes, emits a
    well-formed BENCH_prefix.json, and shows the collapse the refcounts
    are for: pages_on < pages_off once a batch shares a prefix."""
    from benchmarks import prefix_sharing
    monkeypatch.setattr(prefix_sharing, "OUT_PATH",
                        str(tmp_path / "BENCH_prefix.json"))
    result = prefix_sharing.run(quick=True)
    assert (tmp_path / "BENCH_prefix.quick.json").exists()
    assert not (tmp_path / "BENCH_prefix.json").exists()
    assert result["rows"], "sweep cells must be emitted"
    for row in result["rows"]:
        assert {"prefix_len", "batch", "pages_off", "pages_on",
                "saved_prefill_tokens", "capacity_on"} <= set(row)
        assert row["pages_on"] < row["pages_off"]
        assert row["saved_prefill_tokens"] > 0
        assert row["capacity_on"] >= row["capacity_off"]


def test_prefix_sharing_rejects_bad_configs(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, params, cache_kind="dense", prefix_sharing=True)
    with pytest.raises(ValueError, match="multiple"):
        Engine(cfg, params, cache_kind="paged", prefix_sharing=True,
               page_size=24, prefill_chunk=16)
