"""T3 heuristic-dataflow tests: the decision structure of paper §5."""
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.core import dispatch as dsp
from repro.core import plan as plan_mod

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


def test_dense_model_has_exactly_four_projection_shapes():
    """The paper's homogeneity insight: four [K,N] per dense LLM (+head)."""
    cfg = configs.get("phi3-mini-3.8b")
    shapes = dsp.model_gemm_shapes(cfg)
    names = {s.name for s in shapes}
    assert names == {"qkv_proj", "o_proj", "ffn_up", "ffn_down", "lm_head"}


def test_moe_model_adds_expert_shapes():
    shapes = {s.name for s in dsp.model_gemm_shapes(configs.get("dbrx-132b"))}
    assert {"router", "expert_up", "expert_down"} <= shapes


@given(st.sampled_from([(4096, 4096), (4096, 12288), (11008, 4096),
                        (896, 151936)]))
def test_inflection_points_ordered(kn):
    k, n = kn
    e = dsp.find_inflections(k, n)
    assert e.m1 <= e.m2


@given(st.integers(min_value=1, max_value=2048),
       st.sampled_from([(4096, 4096), (4096, 11008)]))
def test_pick_is_piecewise_by_m(m, kn):
    e = dsp.find_inflections(*kn)
    impl = e.pick(m)
    if m < e.m1:
        assert impl is dsp.Impl.GEMV
    elif m < e.m2:
        assert impl is dsp.Impl.FLAT_GEMM
    else:
        assert impl is dsp.Impl.XLA_DOT


def test_cost_model_limits():
    """GEMV must win at M=1; XLA dot must win at M=1024 (paper Fig. 9)."""
    k, n = 4096, 4096
    t_gemv = dsp.predict_time(dsp.Impl.GEMV, 1, k, n)
    t_flat = dsp.predict_time(dsp.Impl.FLAT_GEMM, 1, k, n)
    assert t_gemv <= t_flat
    t_flat = dsp.predict_time(dsp.Impl.FLAT_GEMM, 1024, k, n)
    t_xla = dsp.predict_time(dsp.Impl.XLA_DOT, 1024, k, n)
    assert t_xla <= t_flat * 1.01


def test_unseen_shape_uses_plan_default_policy():
    """One source of truth: the plan's default ladder routes any [K, N]
    the tuning sweep never saw (the old static m<=2 / m<128 policy)."""
    plan = plan_mod.tune(configs.get("qwen2-0.5b"))
    mp = plan.matmul
    assert (17, 23) not in mp.entries
    assert mp.pick(1, 17, 23) is dsp.pick_impl(1, mp.default_m1,
                                               mp.default_m2)
    # the untuned default plan carries the conservative static ladder
    d = plan_mod.MatmulPlan()
    assert d.pick(1, 17, 23) is dsp.Impl.GEMV
    assert d.pick(64, 17, 23) is dsp.Impl.FLAT_GEMM
    assert d.pick(4096, 17, 23) is dsp.Impl.XLA_DOT


def test_matmul_routes_by_plan():
    """ops.matmul must produce oracle-equal results whatever impl the
    plan picks (here on the Pallas backend, interpret mode)."""
    import numpy as np
    from repro.kernels import ops, ref
    import jax
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    plan = plan_mod.tune(cfg, backend="pallas")
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    for m in (1, 8, 200):
        x = jax.random.normal(k1, (m, 128), jnp.float32)
        w = jax.random.normal(k2, (128, 256), jnp.float32)
        got = ops.matmul(x, w, plan=plan)
        np.testing.assert_allclose(got, ref.flat_gemm_ref(x, w),
                                   rtol=2e-4, atol=2e-4)


def test_measured_backend_hook():
    """A custom measure fn drives the decision flow (real-TPU path)."""
    calls = []

    def fake_measure(impl, m, k, n):
        calls.append((impl, m))
        # fabricate a world where flat wins from M=8, xla from M=128
        base = {dsp.Impl.GEMV: 1.0, dsp.Impl.FLAT_GEMM: 2.0,
                dsp.Impl.XLA_DOT: 4.0}[impl]
        if impl is dsp.Impl.FLAT_GEMM and m >= 8:
            base = 0.5
        if impl is dsp.Impl.XLA_DOT and m >= 128:
            base = 0.1
        return base

    e = dsp.find_inflections(1024, 1024, measure=fake_measure)
    assert e.m1 == 8 and e.m2 == 128
    assert calls, "measure backend must be consulted"


def test_block_k_decision_flow():
    """find_block_k: feasible, from the candidate set, and nondecreasing
    in the representative KV length (longer decode amortizes more grid
    steps per byte — the beyond-GEMM analogue of the M1/M2 monotonicity)."""
    kv_dim = 1024
    prev = 0
    for s in (64, 128, 256, 512, 1024, 4096, 32768, 262144):
        bk = dsp.find_block_k(s, kv_dim)
        assert bk in dsp.BLOCK_K_CANDIDATES
        assert bk >= prev, (s, bk, prev)
        prev = bk


def test_chunk_threshold_decision_flow():
    """More heads -> bigger materialized scores -> lower threshold."""
    t_few = dsp.find_chunk_threshold(4)
    t_many = dsp.find_chunk_threshold(64)
    assert t_many <= t_few
    assert t_few in dsp.CHUNK_THRESHOLD_CANDIDATES


def test_wallclock_measure_runs_and_is_positive():
    """The fixed timing hook: independent operand keys, warmup, per-iter
    blocking — must return a sane positive time on any backend."""
    measure = dsp.wallclock_measure_factory(dtype="float32", warmup=1,
                                            iters=2)
    t = measure(dsp.Impl.XLA_DOT, 4, 64, 64)
    assert t > 0.0
    assert t < 60.0


@pytest.mark.parametrize("arch", ["llama2-7b", "dbrx-132b"])
def test_tuned_entries_cover_model_shapes(arch):
    cfg = configs.get(arch)
    plan = plan_mod.tune(cfg)
    for gs in dsp.model_gemm_shapes(cfg):
        assert (gs.k, gs.n) in plan.matmul.entries
