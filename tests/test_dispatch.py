"""T3 heuristic-dataflow tests: the decision structure of paper §5."""
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.core import dispatch as dsp

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


def test_dense_model_has_exactly_four_projection_shapes():
    """The paper's homogeneity insight: four [K,N] per dense LLM (+head)."""
    cfg = configs.get("phi3-mini-3.8b")
    shapes = dsp.model_gemm_shapes(cfg)
    names = {s.name for s in shapes}
    assert names == {"qkv_proj", "o_proj", "ffn_up", "ffn_down", "lm_head"}


def test_moe_model_adds_expert_shapes():
    shapes = {s.name for s in dsp.model_gemm_shapes(configs.get("dbrx-132b"))}
    assert {"router", "expert_up", "expert_down"} <= shapes


@given(st.sampled_from([(4096, 4096), (4096, 12288), (11008, 4096),
                        (896, 151936)]))
def test_inflection_points_ordered(kn):
    k, n = kn
    e = dsp.find_inflections(k, n)
    assert e.m1 <= e.m2


@given(st.integers(min_value=1, max_value=2048),
       st.sampled_from([(4096, 4096), (4096, 11008)]))
def test_pick_is_piecewise_by_m(m, kn):
    e = dsp.find_inflections(*kn)
    impl = e.pick(m)
    if m < e.m1:
        assert impl is dsp.Impl.GEMV
    elif m < e.m2:
        assert impl is dsp.Impl.FLAT_GEMM
    else:
        assert impl is dsp.Impl.XLA_DOT


def test_cost_model_limits():
    """GEMV must win at M=1; XLA dot must win at M=1024 (paper Fig. 9)."""
    k, n = 4096, 4096
    t_gemv = dsp.predict_time(dsp.Impl.GEMV, 1, k, n)
    t_flat = dsp.predict_time(dsp.Impl.FLAT_GEMM, 1, k, n)
    assert t_gemv <= t_flat
    t_flat = dsp.predict_time(dsp.Impl.FLAT_GEMM, 1024, k, n)
    t_xla = dsp.predict_time(dsp.Impl.XLA_DOT, 1024, k, n)
    assert t_xla <= t_flat * 1.01


def test_table_roundtrip_and_fallback():
    cfg = configs.get("qwen2-0.5b")
    table = dsp.tune_table(cfg)
    s = table.to_json()
    table2 = dsp.DispatchTable.from_json(s)
    for (k, n), e in table.entries.items():
        assert table2.entries[(k, n)].m1 == e.m1
        assert table2.entries[(k, n)].m2 == e.m2
    # unseen shape falls back to the static policy, never crashes
    assert table.pick(1, 17, 23) is dsp.Impl.GEMV
    assert table.pick(64, 17, 23) is dsp.Impl.FLAT_GEMM
    assert table.pick(4096, 17, 23) is dsp.Impl.XLA_DOT


def test_matmul_routes_by_table():
    """ops.matmul must produce oracle-equal results whatever impl it picks."""
    import numpy as np
    from repro.kernels import ops, ref
    import jax
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    table = dsp.tune_table(cfg)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    for m in (1, 8, 200):
        x = jax.random.normal(k1, (m, 128), jnp.float32)
        w = jax.random.normal(k2, (128, 256), jnp.float32)
        got = ops.matmul(x, w, table=table, use_pallas=True)
        np.testing.assert_allclose(got, ref.flat_gemm_ref(x, w),
                                   rtol=2e-4, atol=2e-4)


def test_measured_backend_hook():
    """A custom measure fn drives the decision flow (real-TPU path)."""
    calls = []

    def fake_measure(impl, m, k, n):
        calls.append((impl, m))
        # fabricate a world where flat wins from M=8, xla from M=128
        base = {dsp.Impl.GEMV: 1.0, dsp.Impl.FLAT_GEMM: 2.0,
                dsp.Impl.XLA_DOT: 4.0}[impl]
        if impl is dsp.Impl.FLAT_GEMM and m >= 8:
            base = 0.5
        if impl is dsp.Impl.XLA_DOT and m >= 128:
            base = 0.1
        return base

    e = dsp.find_inflections(1024, 1024, measure=fake_measure)
    assert e.m1 == 8 and e.m2 == 128
    assert calls, "measure backend must be consulted"
