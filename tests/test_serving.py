"""Serving engine: continuous batching, slot lifecycle, sampling, and
engine-vs-prefill consistency (greedy decode must match teacher forcing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.api import get_model
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import SlotManager
from repro.serving.sampling import sample


def _engine(arch, **kw):
    cfg = configs.smoke(configs.get(arch))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, Engine(cfg, params, **kw)


def test_slot_manager_lifecycle():
    sm = SlotManager(2, max_seq=32)
    a = sm.try_assign(10, prompt_len=4, max_new=8)
    b = sm.try_assign(11, prompt_len=4, max_new=8)
    assert a == 0 and b == 1
    assert sm.try_assign(12, 4, 8) is None      # full
    assert list(sm.lengths()) == [4, 4]
    sm.tick(a)
    assert list(sm.lengths()) == [5, 4]
    sm.release(a)
    assert sm.try_assign(12, 4, 8) == 0          # slot reused
    with pytest.raises(ValueError):
        sm.try_assign(13, prompt_len=30, max_new=8)  # exceeds max_seq


def test_engine_continuous_batching_queueing():
    cfg, eng = _engine("qwen2-0.5b", num_slots=2, max_seq=128)
    rng = np.random.default_rng(0)
    reqs = [Request(id=i,
                    prompt=rng.integers(1, 100, size=5 + i).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    out = eng.run(reqs)
    assert set(out) == set(range(5))
    assert all(len(v) == 4 for v in out.values())


@pytest.mark.parametrize(
    "arch", ["qwen2-0.5b",
             pytest.param("rwkv6-1.6b", marks=pytest.mark.slow),
             pytest.param("hymba-1.5b", marks=pytest.mark.slow)])
def test_engine_matches_teacher_forcing(arch):
    """Greedy engine output == argmax of prefill(prompt + prefix) at every
    step — continuous batching/ragged prompts do not change the math."""
    cfg, eng = _engine(arch, num_slots=2, max_seq=256)
    api = get_model(cfg)
    params = eng.params
    from repro.models.layers import LayerCtx
    ctx = LayerCtx(cfg=cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 23)]
    out = eng.run([Request(id=i, prompt=p, max_new_tokens=3)
                   for i, p in enumerate(prompts)])
    for i, prompt in enumerate(prompts):
        toks = out[i]
        for k in range(3):
            seq = np.concatenate([prompt, np.asarray(toks[:k], np.int32)])
            # one padded teacher shape -> one jit compile for all (i, k)
            padded = np.zeros((64,), np.int32)
            padded[:len(seq)] = seq
            cache = api.init_cache(1, 256)
            logits, _ = api.prefill(
                ctx, params, jnp.asarray(padded)[None],
                jnp.array([len(seq)], jnp.int32), cache)
            want = int(jnp.argmax(logits[0, :cfg.vocab_size]))
            assert want == toks[k], (arch, i, k)


def test_engine_chunked_prefill_matches_teacher_forcing():
    """Chunked + batched prefill (prompts streamed through the decode-shaped
    path in 16-token chunks, whole admission wave in one padded batch) is
    greedy-equivalent to single-shot ``api.prefill`` teacher forcing for
    ragged prompt lengths spanning 1..4 chunks."""
    cfg, eng = _engine("qwen2-0.5b", num_slots=4, max_seq=256,
                       prefill_chunk=16)
    api = get_model(cfg)
    from repro.models.layers import LayerCtx
    ctx = LayerCtx(cfg=cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 16, 23, 61)]    # below / at / across chunk edges
    out = eng.run([Request(id=i, prompt=p, max_new_tokens=2)
                   for i, p in enumerate(prompts)])
    for i, prompt in enumerate(prompts):
        toks = out[i]
        for k in range(2):
            seq = np.concatenate([prompt, np.asarray(toks[:k], np.int32)])
            # one padded teacher shape -> one jit compile for all (i, k)
            padded = np.zeros((64,), np.int32)
            padded[:len(seq)] = seq
            cache = api.init_cache(1, 256)
            logits, _ = api.prefill(
                ctx, eng.params, jnp.asarray(padded)[None],
                jnp.array([len(seq)], jnp.int32), cache)
            want = int(jnp.argmax(logits[0, :cfg.vocab_size]))
            assert want == toks[k], (i, k)


def test_engine_eos_and_slot_reuse():
    cfg, eng = _engine("qwen2-0.5b", num_slots=1, max_seq=128)
    rng = np.random.default_rng(0)
    # find the first greedy token, then use it as EOS for request 1
    probe = eng.run([Request(id=0, prompt=rng.integers(1, 50, 8).astype(
        np.int32), max_new_tokens=1)])
    eos = probe[0][0]
    eng2_cfg, eng2 = _engine("qwen2-0.5b", num_slots=1, max_seq=128)
    reqs = [
        Request(id=0, prompt=rng.integers(1, 50, 8).astype(np.int32),
                max_new_tokens=10, eos_token=None),
        Request(id=1, prompt=rng.integers(1, 50, 8).astype(np.int32),
                max_new_tokens=10),
    ]
    out = eng2.run(reqs)
    assert len(out[0]) == 10 and len(out[1]) == 10
    del eos


def test_sampling_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]], jnp.float32)
    key = jax.random.PRNGKey(0)
    assert int(sample(logits, key)[0]) == 1                       # greedy
    # vocab mask: ids >= vocab_size never sampled
    toks = [int(sample(logits, jax.random.PRNGKey(i), temperature=5.0,
                       vocab_size=3)[0]) for i in range(50)]
    assert max(toks) <= 2
    # top-k=1 == greedy even at high temperature
    toks = [int(sample(logits, jax.random.PRNGKey(i), temperature=3.0,
                       top_k=1)[0]) for i in range(20)]
    assert set(toks) == {1}


def test_engine_respects_max_seq_budget():
    cfg, eng = _engine("qwen2-0.5b", num_slots=1, max_seq=32)
    with pytest.raises(ValueError):
        eng.run([Request(id=0, prompt=np.arange(1, 30, dtype=np.int32),
                         max_new_tokens=10)])
