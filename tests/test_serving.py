"""Serving engine: request lifecycle, continuous batching, sampling,
streaming/abort, and engine-vs-prefill consistency (greedy decode must
match teacher forcing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.api import get_model
from repro.models.kvlayout import DenseLayout
from repro.serving.engine import Engine
from repro.serving.kvcache import SlotManager
from repro.serving.request import FinishReason, SamplingParams
from repro.serving.sampling import sample


def _engine(arch, **kw):
    cfg = configs.smoke(configs.get(arch))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, Engine(cfg, params, **kw)


def test_slot_manager_lifecycle():
    sm = SlotManager(2, max_seq=32)
    a = sm.try_assign(10, prompt_len=4, max_new=8)
    b = sm.try_assign(11, prompt_len=4, max_new=8)
    assert a == 0 and b == 1
    assert sm.try_assign(12, 4, 8) is None      # full
    assert list(sm.lengths()) == [4, 4]
    sm.tick(a)
    assert list(sm.lengths()) == [5, 4]
    assert sm.block_tables() is None            # dense layout: no operand
    sm.release(a)
    assert sm.try_assign(12, 4, 8) == 0          # slot reused
    with pytest.raises(ValueError):
        sm.try_assign(13, prompt_len=30, max_new=8)  # exceeds max_seq


def test_engine_continuous_batching_queueing():
    cfg, eng = _engine("qwen2-0.5b", num_slots=2, max_seq=128)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(1, 100, size=5 + i).astype(np.int32),
             SamplingParams(max_new_tokens=4)) for i in range(5)]
    out = eng.run(reqs)
    assert set(out) == set(range(5))
    assert all(len(v) == 4 for v in out.values())
    assert all(eng.finish_reason(r) is FinishReason.LENGTH for r in out)


@pytest.mark.parametrize(
    "arch", ["qwen2-0.5b",
             pytest.param("rwkv6-1.6b", marks=pytest.mark.slow),
             pytest.param("hymba-1.5b", marks=pytest.mark.slow)])
def test_engine_matches_teacher_forcing(arch):
    """Greedy engine output == argmax of prefill(prompt + prefix) at every
    step — continuous batching/ragged prompts do not change the math."""
    cfg, eng = _engine(arch, num_slots=2, max_seq=256)
    api = get_model(cfg)
    params = eng.params
    from repro.models.layers import LayerCtx
    ctx = LayerCtx(cfg=cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 23)]
    out = eng.run([(p, SamplingParams(max_new_tokens=3)) for p in prompts])
    for i, prompt in enumerate(prompts):
        toks = out[i]
        for k in range(3):
            seq = np.concatenate([prompt, np.asarray(toks[:k], np.int32)])
            # one padded teacher shape -> one jit compile for all (i, k)
            padded = np.zeros((64,), np.int32)
            padded[:len(seq)] = seq
            cache = api.init_cache(DenseLayout(1, 256))
            logits, _ = api.prefill(
                ctx, params, jnp.asarray(padded)[None],
                jnp.array([len(seq)], jnp.int32), cache)
            want = int(jnp.argmax(logits[0, :cfg.vocab_size]))
            assert want == toks[k], (arch, i, k)


def test_engine_chunked_prefill_matches_teacher_forcing():
    """Chunked + batched prefill (prompts streamed through the decode-shaped
    path in 16-token chunks, whole admission wave in one padded batch) is
    greedy-equivalent to single-shot ``api.prefill`` teacher forcing for
    ragged prompt lengths spanning 1..4 chunks."""
    cfg, eng = _engine("qwen2-0.5b", num_slots=4, max_seq=256,
                       prefill_chunk=16)
    api = get_model(cfg)
    from repro.models.layers import LayerCtx
    ctx = LayerCtx(cfg=cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 16, 23, 61)]    # below / at / across chunk edges
    out = eng.run([(p, SamplingParams(max_new_tokens=2)) for p in prompts])
    for i, prompt in enumerate(prompts):
        toks = out[i]
        for k in range(2):
            seq = np.concatenate([prompt, np.asarray(toks[:k], np.int32)])
            # one padded teacher shape -> one jit compile for all (i, k)
            padded = np.zeros((64,), np.int32)
            padded[:len(seq)] = seq
            cache = api.init_cache(DenseLayout(1, 256))
            logits, _ = api.prefill(
                ctx, eng.params, jnp.asarray(padded)[None],
                jnp.array([len(seq)], jnp.int32), cache)
            want = int(jnp.argmax(logits[0, :cfg.vocab_size]))
            assert want == toks[k], (i, k)


def test_engine_stop_token_and_finish_reason():
    """A sampled stop token ends the request with reason ``stop``; the
    token joins the output only under ``include_stop=True`` and never
    burns ``max_new_tokens`` budget; the freed slot is reused."""
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, 50, 8).astype(np.int32)
    # find the greedy continuation, then use its second token as the stop
    cfg, probe = _engine("qwen2-0.5b", num_slots=1, max_seq=128)
    toks = probe.run([(prompt, SamplingParams(max_new_tokens=4))])[0]
    stop = toks[1]

    _, eng = _engine("qwen2-0.5b", num_slots=1, max_seq=128)
    out = eng.run([
        (prompt, SamplingParams(max_new_tokens=10, stop_tokens=(stop,))),
        (prompt, SamplingParams(max_new_tokens=10, stop_tokens=(stop,),
                                include_stop=True)),
        (prompt, SamplingParams(max_new_tokens=10)),
    ])
    assert out[0] == toks[:1]                    # stop excluded
    assert out[1] == toks[:2]                    # stop included
    assert len(out[2]) == 10                     # no stop -> full budget
    assert eng.finish_reason(0) is FinishReason.STOP
    assert eng.finish_reason(1) is FinishReason.STOP
    assert eng.finish_reason(2) is FinishReason.LENGTH
    # the event stream mirrors run(): an excluded stop token never reaches
    # it (terminal event is token=None), an included one does
    for rid in out:
        streamed = [e.token for e in eng.requests[rid].events
                    if e.token is not None]
        assert streamed == out[rid], rid
    assert eng.requests[0].events[-1].token is None
    assert eng.requests[1].events[-1].token == stop


def test_engine_single_token_requests_drain_queue():
    """max_new_tokens=1 requests finish inside prefill, leaving the batch
    empty while others wait — the engine must keep admitting (not report a
    stall) until the queue drains."""
    cfg, eng = _engine("qwen2-0.5b", num_slots=1, max_seq=64)
    rng = np.random.default_rng(2)
    out = eng.run([(rng.integers(1, 100, 6).astype(np.int32),
                    SamplingParams(max_new_tokens=1)) for _ in range(3)])
    assert all(len(v) == 1 for v in out.values())
    assert all(eng.finish_reason(r) is FinishReason.LENGTH for r in out)


def test_engine_generate_streams_and_aborts():
    """generate() yields TokenEvents incrementally (final event carries
    finished + reason); abort() cancels a co-resident request mid-flight
    and frees its slot for the queue."""
    cfg, eng = _engine("qwen2-0.5b", num_slots=2, max_seq=128)
    rng = np.random.default_rng(1)
    victim = eng.submit(rng.integers(1, 100, 12).astype(np.int32),
                        SamplingParams(max_new_tokens=50))
    events = []
    for ev in eng.generate(rng.integers(1, 100, 9).astype(np.int32),
                           SamplingParams(max_new_tokens=6)):
        events.append(ev)
        if ev.index == 2:
            assert eng.abort(victim)
    assert [e.index for e in events] == list(range(6))
    assert events[-1].finished
    assert events[-1].finish_reason is FinishReason.LENGTH
    assert all(not e.finished for e in events[:-1])
    assert eng.finish_reason(victim) is FinishReason.ABORT
    vic = eng.requests[victim]
    assert 0 < vic.generated < 50
    # streamed tokens match the state's record
    stream_rid = events[0].rid
    assert [e.token for e in events] == eng.requests[stream_rid].tokens


def test_engine_per_request_seed_isolation():
    """Sampled requests own their PRNG stream: the same (prompt, seed)
    draws the same tokens no matter how its batch-mates sample."""
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, 100, 10).astype(np.int32)
    other = rng.integers(1, 100, 14).astype(np.int32)
    sp = SamplingParams(max_new_tokens=5, temperature=0.8, top_k=20, seed=7)

    def crowd(other_sp):
        _, eng = _engine("qwen2-0.5b", num_slots=2, max_seq=128)
        return eng.run([(other, other_sp), (prompt, sp)])[1]

    a = crowd(SamplingParams(max_new_tokens=8, temperature=1.0, seed=123))
    b = crowd(SamplingParams(max_new_tokens=3, temperature=0.3, seed=999))
    assert a == b


def test_sampling_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]], jnp.float32)
    key = jax.random.PRNGKey(0)
    assert int(sample(logits, key)[0]) == 1                       # greedy
    # vocab mask: ids >= vocab_size never sampled
    toks = [int(sample(logits, jax.random.PRNGKey(i), temperature=5.0,
                       vocab_size=3)[0]) for i in range(50)]
    assert max(toks) <= 2
    # top-k=1 == greedy even at high temperature
    toks = [int(sample(logits, jax.random.PRNGKey(i), temperature=3.0,
                       top_k=1)[0]) for i in range(20)]
    assert set(toks) == {1}


def test_sampling_top_p_distribution():
    """Nucleus sampling: the kept set is exactly the smallest prefix whose
    cumulative probability reaches top_p, and the empirical distribution
    over many draws tracks the renormalized probabilities."""
    # probs 0.5 / 0.25 / 0.125 / 0.0625 / 0.0625
    logits = jnp.log(jnp.asarray([[0.5, 0.25, 0.125, 0.0625, 0.0625]]))
    draws = [int(sample(logits, jax.random.PRNGKey(i), temperature=1.0,
                        top_p=0.5)[0]) for i in range(40)]
    assert set(draws) == {0}                    # nucleus = top token only
    draws = [int(sample(logits, jax.random.PRNGKey(i), temperature=1.0,
                        top_p=0.8)[0]) for i in range(400)]
    assert set(draws) <= {0, 1, 2}              # 0.5+0.25+0.125 >= 0.8
    freq0 = draws.count(0) / len(draws)
    assert 0.45 <= freq0 <= 0.70                # ~0.5/0.875 = 0.57
    # top_p -> 1 keeps everything reachable
    draws = [int(sample(logits, jax.random.PRNGKey(i), temperature=1.0,
                        top_p=1.0)[0]) for i in range(400)]
    assert set(draws) == {0, 1, 2, 3, 4}


def test_engine_respects_max_seq_budget():
    """Unservable requests are rejected at submit() — before any
    batch-mate claims a slot — for both the max_seq and the page-pool
    worst-case bounds."""
    cfg, eng = _engine("qwen2-0.5b", num_slots=1, max_seq=32)
    with pytest.raises(ValueError):
        eng.run([(np.arange(1, 30, dtype=np.int32),
                  SamplingParams(max_new_tokens=10))])
    assert not eng.requests and not eng.waiting      # nothing half-admitted
    cfg2, paged = _engine("qwen2-0.5b", num_slots=1, max_seq=512,
                          cache_kind="paged", page_size=64, num_pages=2)
    with pytest.raises(ValueError):
        paged.submit(np.arange(1, 200, dtype=np.int32),
                     SamplingParams(max_new_tokens=100))   # 5 > 2 pages


def test_engine_evicts_finished_state():
    """Long-lived servers can drop retained per-request state once
    consumed; unfinished requests must be aborted first."""
    cfg, eng = _engine("qwen2-0.5b", num_slots=1, max_seq=64)
    rng = np.random.default_rng(0)
    out = eng.run([(rng.integers(1, 100, 6).astype(np.int32),
                    SamplingParams(max_new_tokens=2)) for _ in range(2)])
    assert eng.evict(0) == out[0]
    assert 0 not in eng.requests
    waiting_rid = eng.submit(rng.integers(1, 100, 6).astype(np.int32))
    with pytest.raises(ValueError):
        eng.evict(waiting_rid)                       # not finished
    eng.abort(waiting_rid)
    assert eng.evict_finished() == 2                 # rid 1 + the aborted
    assert not eng.requests
